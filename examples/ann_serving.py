"""Distributed ANN serving: the paper's engine sharded over a device mesh,
with batched query requests — the end-to-end driver for the serving kind.

Runs on 8 virtual host devices (set before jax import) to demonstrate the
actual multi-chip SPMD program; the same code targets the 256/512-chip
production meshes via launch/mesh.py.

    PYTHONPATH=src python examples/ann_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.core import JunoConfig, build, exact_topk, recall_1_at_k
from repro.data import DEEP_LIKE, make_dataset
from repro.dist.distributed_index import (DistributedMutableIndex,
                                          make_distributed_search,
                                          shard_index)
from repro.serve import AnnServeEngine, AnnServeFleet


def serve_online(index, points, queries, gt):
    """Online serving: dynamic batching + recall routing + live mutation."""
    engine = AnnServeEngine(index, batch_buckets=(8, 16, 32))
    reqs = [engine.submit(queries[i * 4:(i + 1) * 4], k=10,
                          recall_target=[0.95, 0.85, 0.55, 0.3][i % 4])
            for i in range(16)]
    t0 = time.time()
    served = engine.run()
    print(f"engine: {served} queries in {time.time() - t0:.2f}s over "
          f"{engine.stats['ticks']} ticks "
          f"({len(engine.stats['signatures'])} jit signatures); "
          f"modes routed: "
          f"{sorted({s[1] for s in engine.stats['signatures']})}")
    r1 = np.mean([float(recall_1_at_k(r.ids, gt[i * 4:(i + 1) * 4, 0]))
                  for i, r in enumerate(reqs)])
    print(f"mean R1@10 across SLAs = {r1:.3f}")

    # live mutation: insert → searchable; delete → gone; no rebuild anywhere
    new = np.asarray(queries[:4]) * 1.0
    ids = engine.insert(new)
    req = engine.submit(new, k=10, mode="H", nprobe=16)
    engine.run()
    hits = sum(ids[j] in req.ids[j] for j in range(4))
    engine.delete(ids[:2])
    print(f"inserted 4 (found {hits}/4), deleted 2, "
          f"side buffer fill: {engine.index.side_fill}")

    # fused two-stage serving: H and H2 tiers coalesce onto one signature
    feng = AnnServeEngine(index, batch_buckets=(8, 16, 32), fused=True)
    freqs = [feng.submit(queries[i * 4:(i + 1) * 4], k=10,
                         recall_target=[0.95, 0.85][i % 2])
             for i in range(8)]
    feng.run()
    fr1 = np.mean([float(recall_1_at_k(r.ids, gt[i * 4:(i + 1) * 4, 0]))
                   for i, r in enumerate(freqs)])
    print(f"fused engine: H+H2 tiers in {feng.stats['ticks']} tick(s) "
          f"({len(feng.stats['signatures'])} signature), "
          f"mean R1@10 = {fr1:.3f}")


def serve_rt_prefilter(index, queries, gt):
    """RT-prefilter serving: sphere-intersection pruning + probe shrink."""
    eng = AnnServeEngine(index, batch_buckets=(8, 16, 32), prefilter="rt")
    reqs = [eng.submit(queries[i], k=10, recall_target=0.85)
            for i in range(32)]
    eng.run()
    r1 = np.mean([float(recall_1_at_k(r.ids[None] if r.ids.ndim == 1
                                      else r.ids, gt[i:i + 1, 0]))
                  for i, r in enumerate(reqs)])
    nprobes = sorted({s[2] for s in eng.stats["signatures"]})
    print(f"rt-prefilter engine: 32 point lookups in "
          f"{eng.stats['ticks']} tick(s), probe budgets routed to "
          f"{nprobes}, mean R1@10 = {r1:.3f} "
          f"(grid: {eng.index.rt_grid.n_cells} cells, "
          f"cap {eng.index.rt_grid.capacity})")


def serve_fleet(index, queries):
    """Replica fleet: 2 replicas x 2 shards, admission control, tail stats."""
    fleet = AnnServeFleet(index, n_replicas=2, shards_per_replica=2,
                          policy="shed", max_queue=64, batch_buckets=(8, 16))
    reqs = [fleet.submit(queries[i * 2:(i + 1) * 2], k=10, mode="M",
                         nprobe=8) for i in range(24)]
    fleet.run()
    fleet.insert(np.asarray(queries[:4]))          # fans out to both replicas
    fleet.fail_replica(0)                          # routing-level failover
    more = [fleet.submit(queries[i * 2:(i + 1) * 2], k=10, mode="M",
                         nprobe=8) for i in range(4)]
    fleet.run()
    fleet.restore_replica(0)
    summ = fleet.latency_summary()
    per = [dict(c) for c in fleet.stats["per_replica"]]
    print(f"fleet (2x2 on {fleet.engines[0].index.n_shards}-shard "
          f"sub-meshes): served {summ['served']} "
          f"(shed {summ['shed']}, rerouted {summ['rerouted']}), "
          f"p50/p95/p99 = {summ['p50'] * 1e3:.0f}/{summ['p95'] * 1e3:.0f}/"
          f"{summ['p99'] * 1e3:.0f} ms, per-replica {per}")
    assert all(r.done for r in reqs + more)


def serve_distributed_mutable(index, queries, mesh):
    """Sharded mutable serving: inserts routed to the owning shard."""
    dmi = DistributedMutableIndex(index, mesh, side_capacity=128)
    dsearch = dmi.searcher(local_nprobe=2, k=10, mode="H")
    ids = dmi.insert(np.asarray(queries[:8]))
    _, got = dsearch(dmi.data, queries[:8], dmi.side)
    hits = sum(ids[j] in np.asarray(got)[j] for j in range(8))
    print(f"distributed insert: {hits}/8 found through the sharded engine "
          f"(scatter routed by owning cluster, side fill {dmi.side_fill})")


def main():
    print(f"devices: {len(jax.devices())}")
    points, queries = make_dataset(DEEP_LIKE, 40_000, 256,
                                   key=jax.random.PRNGKey(1))
    cfg = JunoConfig(n_clusters=64, n_entries=64, calib_queries=48)
    index = build(points, cfg)
    _, gt = exact_topk(queries, points, k=100)

    mesh = jax.make_mesh((8,), ("data",))
    sharded = shard_index(index, mesh)
    print("index sharded:", sharded.cluster_codes.sharding)

    dsearch = make_distributed_search(mesh, local_nprobe=2, k=100, mode="H2")

    # batched request loop (16 requests of 16 queries each)
    total_q, t_total = 0, 0.0
    recalls = []
    for i in range(16):
        qb = queries[i * 16:(i + 1) * 16]
        t0 = time.time()
        scores, ids = dsearch(sharded, qb)
        jax.block_until_ready(ids)
        t_total += time.time() - t0
        total_q += qb.shape[0]
        recalls.append(float(recall_1_at_k(ids, gt[i * 16:(i + 1) * 16, 0])))
    print(f"served {total_q} queries in {t_total:.2f}s "
          f"({total_q / t_total:.0f} QPS on CPU-interp mesh)")
    print(f"mean R1@100 = {np.mean(recalls):.3f}")

    serve_online(index, points, queries, gt)
    serve_rt_prefilter(index, np.asarray(queries), gt)
    serve_distributed_mutable(index, queries, mesh)
    serve_fleet(index, np.asarray(queries))


if __name__ == "__main__":
    main()

"""Distributed ANN serving: the paper's engine sharded over a device mesh,
with batched query requests — the end-to-end driver for the serving kind.

Runs on 8 virtual host devices (set before jax import) to demonstrate the
actual multi-chip SPMD program; the same code targets the 256/512-chip
production meshes via launch/mesh.py.

    PYTHONPATH=src python examples/ann_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.core import JunoConfig, build, exact_topk, recall_1_at_k
from repro.data import DEEP_LIKE, make_dataset
from repro.dist.distributed_index import (make_distributed_search,
                                          shard_index)


def main():
    print(f"devices: {len(jax.devices())}")
    points, queries = make_dataset(DEEP_LIKE, 40_000, 256,
                                   key=jax.random.PRNGKey(1))
    cfg = JunoConfig(n_clusters=64, n_entries=64, calib_queries=48)
    index = build(points, cfg)
    _, gt = exact_topk(queries, points, k=100)

    mesh = jax.make_mesh((8,), ("data",))
    sharded = shard_index(index, mesh)
    print("index sharded:", sharded.cluster_codes.sharding)

    dsearch = make_distributed_search(mesh, local_nprobe=2, k=100, mode="H2")

    # batched request loop (16 requests of 16 queries each)
    total_q, t_total = 0, 0.0
    recalls = []
    for i in range(16):
        qb = queries[i * 16:(i + 1) * 16]
        t0 = time.time()
        scores, ids = dsearch(sharded, qb)
        jax.block_until_ready(ids)
        t_total += time.time() - t0
        total_q += qb.shape[0]
        recalls.append(float(recall_1_at_k(ids, gt[i * 16:(i + 1) * 16, 0])))
    print(f"served {total_q} queries in {t_total:.2f}s "
          f"({total_q / t_total:.0f} QPS on CPU-interp mesh)")
    print(f"mean R1@100 = {np.mean(recalls):.3f}")


if __name__ == "__main__":
    main()

"""JUNO-attention in an LM decode loop (beyond-paper, paper §6.5 direction).

Prefill a small LM, PQ-index its KV cache, then decode comparing exact
attention vs JUNO top-C attention: agreement of attended outputs, and the
memory-traffic model that makes it a win on memory-bound decode.

    PYTHONPATH=src python examples/juno_attention_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.models.juno_attention import (build_kv_index,
                                         juno_decode_attention,
                                         traffic_model)
from repro.models.layers import attention
from repro.models.params import init_params


def main():
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = get_model(cfg)
    params = init_params(model.schema, jax.random.PRNGKey(0))

    # prefill 96 tokens
    s_max, prompt_len, b = 128, 96, 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len), 0,
                                cfg.vocab_size).astype(jnp.int32)
    cache = init_params(model.cache_schema(b, s_max), jax.random.PRNGKey(2))
    _, cache = model.prefill(params, {"tokens": tokens}, cache)

    # take layer 0's cache and a random query; compare attention outputs
    k_cache = cache["blocks"]["k"][0]      # (B, S, KVH, hd)
    v_cache = cache["blocks"]["v"][0]
    pos = jnp.full((b,), prompt_len, jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(3),
                          (b, 1, cfg.n_heads, cfg.head_dim),
                          k_cache.dtype) * 0.5

    exact = attention(q, k_cache, v_cache, causal=True,
                      q_offset=pos, kv_len=pos + 1, chunk=64)

    index = build_kv_index(k_cache, n_entries=16)
    for top_c in [8, 24, 64, 96]:
        approx = juno_decode_attention(q, index, k_cache, v_cache, pos,
                                       top_c=top_c)
        err = float(jnp.linalg.norm(approx - exact)
                    / jnp.linalg.norm(exact))
        cos = float(jnp.sum(approx * exact)
                    / (jnp.linalg.norm(approx) * jnp.linalg.norm(exact)))
        print(f"top_c={top_c:4d}  rel_err={err:.3f}  cosine={cos:.4f}")

    print("\nmemory-traffic model at production scale (decode_32k, hd=128):")
    for top_c in [256, 512, 1024]:
        t = traffic_model(32_768, 128, top_c)
        print(f"  top_c={top_c:5d}: exact={t['exact_bytes'] / 1e6:.1f}MB/head"
              f"  juno={t['juno_bytes'] / 1e6:.2f}MB/head"
              f"  -> {t['reduction_x']:.1f}x less HBM traffic")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: trains the ~100M-param smoke variant
of deepseek-coder for a few hundred steps on the deterministic synthetic
pipeline, with checkpoint/resume — the 'train a small model end to end'
deliverable. (The full-size configs use the same driver via launch/train.py
on a real pod.)

    PYTHONPATH=src python examples/train_smoke_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_smoke")
    args = ap.parse_args()

    losses = train_main([
        "--arch", "deepseek_coder_33b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50", "--resume",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Quickstart: build a JUNO index and search it — the paper's pipeline
end-to-end on synthetic deep-like data (CPU, <1 min).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import (JunoConfig, build, exact_topk, recall_1_at_k,
                        recall_n_at_k, search)
from repro.data import DEEP_LIKE, make_dataset


def main():
    print("generating 50k-point deep-like dataset (96-d, L2)...")
    points, queries = make_dataset(DEEP_LIKE, 50_000, 128,
                                   key=jax.random.PRNGKey(0))

    print("building JUNO index (IVF k-means -> residual PQ -> density "
          "calibration)...")
    t0 = time.time()
    cfg = JunoConfig(n_clusters=256, n_entries=128, calib_queries=64)
    index = build(points, cfg)
    print(f"  built in {time.time() - t0:.1f}s: C={cfg.n_clusters} "
          f"E={cfg.n_entries} subspaces={points.shape[1] // cfg.sub_dim}")

    _, gt = exact_topk(queries, points, k=100)

    print(f"\n{'mode':8s} {'R1@100':>8s} {'R100@1k':>8s} {'ms/query':>9s}")
    for mode, label in [("H", "JUNO-H (exact selective)"),
                        ("H2", "JUNO-H2 (two-stage, beyond-paper)"),
                        ("M", "JUNO-M (reward/penalty hit count)"),
                        ("L", "JUNO-L (plain hit count)")]:
        t0 = time.time()
        _, ids = search(index, queries, nprobe=16, k=100, mode=mode)
        jax.block_until_ready(ids)
        t0 = time.time()  # warm second pass
        _, ids = search(index, queries, nprobe=16, k=100, mode=mode)
        jax.block_until_ready(ids)
        dt = (time.time() - t0) / queries.shape[0] * 1e3
        r1 = float(recall_1_at_k(ids, gt[:, 0]))
        r100 = float(recall_n_at_k(ids, gt[:, :100]))
        print(f"{mode:8s} {r1:8.3f} {r100:8.3f} {dt:9.2f}   # {label}")


if __name__ == "__main__":
    main()

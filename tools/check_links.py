"""Intra-repo markdown link checker (stdlib only — the docs-check CI gate).

Scans the given markdown files (default: README.md, DESIGN.md, docs/*.md)
for inline links/images ``[text](target)`` and fails on any *intra-repo*
target that does not exist on disk, resolving relative targets against
the containing file. External schemes (http/https/mailto) and pure
in-page anchors (``#...``) are skipped; a ``path#anchor`` target is
checked for the path part only.

With ``--orphans`` it additionally fails on ORPHAN docs pages: a page
under ``docs/`` that no other scanned markdown file links to (every page
must be reachable from the docs site, not just exist).

    python tools/check_links.py [--orphans] [FILES...]

Exit code = number of dead links (+ orphan pages). Also runnable
in-process (tests/test_docs_links.py) so the guarantee holds in tier 1.
"""
from __future__ import annotations

import glob
import os
import re
import sys

# inline markdown link/image: [text](target) — good enough for these docs
# (no reference-style links in the tree); ignores fenced code by requiring
# the target to not contain whitespace
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def dead_links(paths: list[str]) -> list[tuple[str, int, str]]:
    """Return (file, line_number, target) for every dead intra-repo link.

    Parameters
    ----------
    paths : list of str
        Markdown files to scan.

    Returns
    -------
    list of tuple
        One entry per dead link, in scan order.
    """
    bad = []
    for path in paths:
        base = os.path.dirname(os.path.abspath(path))
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                for target in _LINK.findall(line):
                    if target.startswith(_SKIP_SCHEMES):
                        continue
                    if target.startswith("#"):
                        continue        # in-page anchor
                    rel = target.split("#", 1)[0]
                    if not rel:
                        continue
                    if not os.path.exists(os.path.join(base, rel)):
                        bad.append((path, lineno, target))
    return bad


def default_files(root: str | None = None) -> list[str]:
    """The file set the docs-check job scans, rooted at the repo root."""
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = [os.path.join(root, "README.md"), os.path.join(root, "DESIGN.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def orphan_pages(root: str | None = None) -> list[str]:
    """Return docs pages no other scanned markdown file links to.

    A page in ``docs/`` must be REACHABLE — linked from README.md,
    DESIGN.md, or another docs page — not merely present. ``index.md``
    is the root of the docs site and is exempt (README links it).

    Parameters
    ----------
    root : str, optional
        Repo root (default: inferred from this file's location).

    Returns
    -------
    list of str
        Absolute paths of orphan pages, sorted.
    """
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = default_files(root)
    linked: set[str] = set()
    for path in files:
        base = os.path.dirname(os.path.abspath(path))
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                for target in _LINK.findall(line):
                    if target.startswith(_SKIP_SCHEMES + ("#",)):
                        continue
                    rel = target.split("#", 1)[0]
                    if not rel:
                        continue
                    dest = os.path.normpath(os.path.join(base, rel))
                    if dest != os.path.normpath(os.path.abspath(path)):
                        linked.add(dest)    # self-links don't count
    docs_dir = os.path.join(root, "docs")
    return sorted(
        page for page in glob.glob(os.path.join(docs_dir, "*.md"))
        if os.path.normpath(os.path.abspath(page)) not in linked
        and os.path.basename(page) != "index.md")


def main(argv: list[str]) -> int:
    """CLI entry point; returns dead links + (with --orphans) orphan pages."""
    check_orphans = "--orphans" in argv
    argv = [a for a in argv if a != "--orphans"]
    files = argv or default_files()
    bad = dead_links(files)
    for path, lineno, target in bad:
        print(f"{path}:{lineno}: dead link -> {target}")
    n_bad = len(bad)
    if check_orphans:
        orphans = orphan_pages()
        for page in orphans:
            print(f"{page}: orphan docs page (linked from nowhere)")
        n_bad += len(orphans)
    print(f"checked {len(files)} files: "
          f"{'OK' if not n_bad else f'{n_bad} problem(s)'}")
    return n_bad


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

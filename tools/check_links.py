"""Intra-repo markdown link checker (stdlib only — the docs-check CI gate).

Scans the given markdown files (default: README.md, DESIGN.md, docs/*.md)
for inline links/images ``[text](target)`` and fails on any *intra-repo*
target that does not exist on disk, resolving relative targets against
the containing file. External schemes (http/https/mailto) and pure
in-page anchors (``#...``) are skipped; a ``path#anchor`` target is
checked for the path part only.

    python tools/check_links.py [FILES...]

Exit code = number of dead links. Also runnable in-process
(tests/test_docs_links.py) so the guarantee holds in tier 1.
"""
from __future__ import annotations

import glob
import os
import re
import sys

# inline markdown link/image: [text](target) — good enough for these docs
# (no reference-style links in the tree); ignores fenced code by requiring
# the target to not contain whitespace
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def dead_links(paths: list[str]) -> list[tuple[str, int, str]]:
    """Return (file, line_number, target) for every dead intra-repo link.

    Parameters
    ----------
    paths : list of str
        Markdown files to scan.

    Returns
    -------
    list of tuple
        One entry per dead link, in scan order.
    """
    bad = []
    for path in paths:
        base = os.path.dirname(os.path.abspath(path))
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                for target in _LINK.findall(line):
                    if target.startswith(_SKIP_SCHEMES):
                        continue
                    if target.startswith("#"):
                        continue        # in-page anchor
                    rel = target.split("#", 1)[0]
                    if not rel:
                        continue
                    if not os.path.exists(os.path.join(base, rel)):
                        bad.append((path, lineno, target))
    return bad


def default_files(root: str | None = None) -> list[str]:
    """The file set the docs-check job scans, rooted at the repo root."""
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = [os.path.join(root, "README.md"), os.path.join(root, "DESIGN.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def main(argv: list[str]) -> int:
    """CLI entry point; returns the number of dead links found."""
    files = argv or default_files()
    bad = dead_links(files)
    for path, lineno, target in bad:
        print(f"{path}:{lineno}: dead link -> {target}")
    print(f"checked {len(files)} files: "
          f"{'OK' if not bad else f'{len(bad)} dead link(s)'}")
    return len(bad)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

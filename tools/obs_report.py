"""Render or validate a ``juno.obs.v1`` JSONL metrics/trace dump.

Reads an event dump produced by ``repro.obs.write_jsonl`` (e.g. via
``benchmarks/serve_qps.py --emit-metrics PATH``), rebuilds the metrics
registry and span list from it, and prints a human-oriented report:
the Prometheus-text exposition of every metric series followed by a
per-name span summary (count, total/max duration). The module only
needs ``repro.obs`` — numpy + stdlib, no jax — so it runs anywhere the
dump can be copied to, including boxes without the accelerator stack.

With ``--validate`` it instead runs ``repro.obs.validate_events`` over
the raw events and exits non-zero listing every schema problem — the CI
smoke step uses this to gate that emitted dumps stay loadable.

    python tools/obs_report.py PATH [--validate] [--no-spans]

Exit code: 0 on success; with ``--validate``, the number of problems
found (capped at 120 by the shell's exit-status width anyway).
"""
from __future__ import annotations

import argparse
import os
import sys
from collections import defaultdict

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.obs import read_jsonl, registry_from_events, validate_events  # noqa: E402
from repro.obs.trace import Tracer  # noqa: E402


def span_summary(events: list[dict]) -> list[str]:
    """Per-name span rollup lines: count, total and max duration.

    Spans are grouped by name across every trace in the dump; durations
    come straight from the recorded ``t_start``/``t_end`` pairs.
    """
    spans = Tracer.spans_from_events(ev for ev in events
                                     if ev.get("event") == "span")
    agg: dict[str, list[float]] = defaultdict(list)
    for s in spans:
        agg[s.name].append(s.duration)
    lines = []
    for name in sorted(agg):
        durs = agg[name]
        lines.append(f"{name:<24} n={len(durs):<6} "
                     f"total_s={sum(durs):.4f} max_s={max(durs):.6f}")
    return lines


def main(argv: list[str] | None = None) -> int:
    """CLI entry point: render (default) or ``--validate`` a dump."""
    ap = argparse.ArgumentParser(
        description="render/validate a juno.obs.v1 JSONL dump")
    ap.add_argument("path", help="JSONL event dump "
                    "(serve_qps.py --emit-metrics output)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the events; exit = problem count")
    ap.add_argument("--no-spans", action="store_true",
                    help="skip the span summary section")
    args = ap.parse_args(argv)

    events = read_jsonl(args.path)
    if args.validate:
        problems = validate_events(events)
        for p in problems:
            print(f"PROBLEM: {p}", file=sys.stderr)
        print(f"{args.path}: {len(events)} events, "
              f"{len(problems)} problems")
        return min(len(problems), 120)

    registry = registry_from_events(events)
    meta = next((ev for ev in events if ev.get("event") == "meta"), {})
    extras = {k: v for k, v in meta.items()
              if k not in ("event", "schema")}
    print(f"# schema={meta.get('schema', '?')} "
          + " ".join(f"{k}={v}" for k, v in sorted(extras.items())))
    sys.stdout.write(registry.render_text())
    if not args.no_spans:
        lines = span_summary(events)
        if lines:
            print("\n# spans")
            for line in lines:
                print(line)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
